"""Checkpointing: params/opt-state pytrees -> npz + msgpack metadata.

No orbax on this box; this is a small, dependency-light, restart-correct
implementation: leaves are keyed by their flattened tree path, dtypes and
the treedef structure are recorded, and restore validates both.  Sharded
arrays are gathered host-side (fine at example scale; production would
swap in per-shard files behind the same interface — the interface is what
the rest of the framework depends on).

Crash safety (DESIGN.md §8):

* **Atomic writes.**  Every file lands via ``tmp + os.replace`` — a crash
  mid-save can leave stale ``*.tmp`` litter but never a half-written
  checkpoint file.  Write ORDER is npz -> meta -> ``latest``: the
  ``latest`` pointer only ever names a checkpoint whose payload and meta
  are both fully on disk, so the observable partial states are exactly
  (a) nothing for the new step, or (b) npz without meta — both of which
  :func:`restore_with_fallback` walks past.
* **Integrity.**  The meta records a crc32 per saved array;
  :func:`restore` re-hashes on load and raises
  :class:`CheckpointCorruptError` on any mismatch (torn write, bit rot)
  instead of handing corrupt weights to the trainer.
* **Fallback.**  :func:`restore_with_fallback` starts at the newest
  checkpoint and walks back to the newest INTACT one (bounded retries),
  so one bad file costs one save interval, not the run.

Failure taxonomy — callers branch on types, never on assert text:

* :class:`CheckpointCorruptError` — unreadable/truncated/crc-mismatched
  files; retrying an OLDER checkpoint may succeed.
* :class:`CheckpointStructureError` — the checkpoint is intact but does
  not match the restore templates (different keys / shapes / dtypes);
  walking back will NOT help, the run configuration changed.  Carries
  ``.tree`` (the mismatched tree name) and ``.detail``.
"""

from __future__ import annotations

import os
import zipfile
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.core import retry as retry_mod


class CheckpointError(Exception):
    """Base class for checkpoint failures."""


class CheckpointCorruptError(CheckpointError):
    """Missing/truncated/crc-mismatched files — an older step may be intact."""


class CheckpointStructureError(CheckpointError):
    """Intact checkpoint, incompatible with the restore templates."""

    def __init__(self, tree: str, detail: str):
        self.tree = tree
        self.detail = detail
        super().__init__(f"checkpoint tree {tree!r} incompatible: {detail}")


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out[key] = leaf
    return out


def _atomic_write(path: str, payload: bytes) -> None:
    """Write-then-rename: readers never observe a partial file."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def save(path: str, step: int, trees: dict[str, Any], extra: dict | None = None):
    """Save named pytrees (e.g. {'params': ..., 'opt_state': ...}).

    Atomic + ordered: npz, then meta (with per-array crc32), then the
    ``latest`` pointer — see the module docstring for the crash states
    this ordering permits.
    """
    os.makedirs(path, exist_ok=True)
    arrays = {}
    meta: dict[str, Any] = {"step": step, "trees": {}, "extra": extra or {}}
    for name, tree in trees.items():
        flat = _flatten_with_paths(tree)
        keys = sorted(flat)
        host = {k: np.asarray(flat[k]) for k in keys}
        meta["trees"][name] = {
            "keys": keys,
            "dtypes": {k: str(host[k].dtype) for k in keys},
            "shapes": {k: list(host[k].shape) for k in keys},
            "crc32": {
                k: zlib.crc32(np.ascontiguousarray(host[k]).tobytes())
                for k in keys
            },
            "treedef": str(jax.tree_util.tree_structure(tree)),
        }
        for k in keys:
            arrays[f"{name}::{k}"] = host[k]
    npz_path = os.path.join(path, f"ckpt_{step}.npz")
    tmp = npz_path + ".tmp"
    # np.savez appends ".npz" unless the handle is ours — write the bytes
    # through the same atomic path as everything else
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, npz_path)
    _atomic_write(os.path.join(path, f"ckpt_{step}.meta"), msgpack.packb(meta))
    _atomic_write(os.path.join(path, "latest"), str(step).encode())


def latest_step(path: str) -> int | None:
    """Step named by the ``latest`` pointer; None when the pointer is
    missing, empty, or garbage (a half-written/corrupted pointer must
    route callers to the fallback scan, not crash the launcher)."""
    p = os.path.join(path, "latest")
    if not os.path.exists(p):
        return None
    try:
        text = open(p).read().strip()
        return int(text) if text else None
    except (ValueError, OSError):
        return None


def available_steps(path: str) -> list[int]:
    """Steps with BOTH payload and meta on disk, ascending.  (The atomic
    write ordering means an npz without a meta is a crashed save — it is
    not listed, and the fallback walks past it for free.)"""
    if not os.path.isdir(path):
        return []
    steps = []
    for fn in os.listdir(path):
        if fn.startswith("ckpt_") and fn.endswith(".meta"):
            try:
                s = int(fn[len("ckpt_"):-len(".meta")])
            except ValueError:
                continue
            if os.path.exists(os.path.join(path, f"ckpt_{s}.npz")):
                steps.append(s)
    return sorted(steps)


def read_meta(path: str, step: int) -> dict:
    """The (msgpack) meta for ``step``; CheckpointCorruptError when
    missing or undecodable."""
    p = os.path.join(path, f"ckpt_{step}.meta")
    try:
        with open(p, "rb") as f:
            return msgpack.unpackb(f.read())
    except (OSError, msgpack.UnpackException, ValueError) as e:
        raise CheckpointCorruptError(f"unreadable meta {p}: {e}") from e


def template_mismatch(meta: dict, name: str, template) -> str | None:
    """Why ``template`` cannot be restored from tree ``name`` of ``meta``
    (None = compatible).  The explicit-detection primitive the launcher
    uses instead of try/except around a full restore: names the missing
    tree, the differing keys, or the first shape/dtype conflict."""
    if name not in meta.get("trees", {}):
        return f"tree {name!r} not in checkpoint (has {sorted(meta['trees'])})"
    saved = meta["trees"][name]
    flat = _flatten_with_paths(template)
    keys = sorted(flat)
    if keys != saved["keys"]:
        diff = sorted(set(keys) ^ set(saved["keys"]))
        return f"leaf keys differ: {diff[:6]}{'...' if len(diff) > 6 else ''}"
    shapes = saved.get("shapes")
    dtypes = saved.get("dtypes", {})
    for k in keys:
        want = np.asarray(flat[k])
        if shapes is not None and list(want.shape) != list(shapes[k]):
            return (f"leaf {k!r} shape {tuple(shapes[k])} != template "
                    f"{tuple(want.shape)}")
        if k in dtypes and str(want.dtype) != dtypes[k]:
            return f"leaf {k!r} dtype {dtypes[k]} != template {want.dtype}"
    return None


def _load_arrays(path: str, step: int):
    p = os.path.join(path, f"ckpt_{step}.npz")
    try:
        return np.load(p)
    except (OSError, ValueError, zlib.error, zipfile.BadZipFile, EOFError) as e:
        raise CheckpointCorruptError(f"unreadable npz {p}: {e}") from e


def restore(path: str, templates: dict[str, Any], step: int | None = None):
    """Restore into the structure of ``templates`` (same named pytrees).

    Returns (step, {name: tree}).  Raises
    :class:`CheckpointStructureError` on key/shape/dtype mismatches (a
    saved-vs-template dtype difference is an ERROR, never a silent cast —
    an f32 optimizer accumulator restored into bf16 would quietly lose
    the run's state) and :class:`CheckpointCorruptError` on unreadable
    or crc-mismatched files.
    """
    if step is None:
        step = latest_step(path)
        if step is None:
            raise CheckpointCorruptError(f"no usable 'latest' pointer at {path}")
    meta = read_meta(path, step)
    data = _load_arrays(path, step)
    out = {}
    for name, template in templates.items():
        mismatch = template_mismatch(meta, name, template)
        if mismatch is not None:
            raise CheckpointStructureError(name, mismatch)
        crcs = meta["trees"][name].get("crc32")  # absent in pre-crc ckpts
        leaves, treedef = jax.tree_util.tree_flatten(template)
        path_order = [
            "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
            for pth, _ in jax.tree_util.tree_flatten_with_path(template)[0]
        ]
        new_leaves = []
        for pth, leaf in zip(path_order, leaves):
            try:
                arr = data[f"{name}::{pth}"]
            except (KeyError, OSError, ValueError, zlib.error,
                    zipfile.BadZipFile, EOFError) as e:
                raise CheckpointCorruptError(
                    f"array {name}::{pth} unreadable in ckpt_{step}.npz: {e}"
                ) from e
            if crcs is not None:
                got = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if got != crcs[pth]:
                    raise CheckpointCorruptError(
                        f"crc mismatch for {name}::{pth} in ckpt_{step}.npz "
                        f"(stored {crcs[pth]}, computed {got})"
                    )
            if arr.shape != leaf.shape:
                raise CheckpointStructureError(
                    name, f"leaf {pth!r} shape {arr.shape} != template "
                          f"{leaf.shape}")
            if str(arr.dtype) != str(np.asarray(leaf).dtype):
                raise CheckpointStructureError(
                    name, f"leaf {pth!r} dtype {arr.dtype} != template "
                          f"{np.asarray(leaf).dtype} (refusing to cast)")
            new_leaves.append(jnp.asarray(arr))
        out[name] = jax.tree_util.tree_unflatten(treedef, new_leaves)
    return step, out


def restore_with_fallback(
    path: str,
    templates: dict[str, Any],
    allow_reset: tuple = (),
    max_retries: int = 3,
):
    """Restore the newest INTACT checkpoint, walking back past corrupt ones.

    Candidate order: the ``latest`` pointer's step first (when usable),
    then every on-disk step descending.  A :class:`CheckpointCorruptError`
    (torn npz, crc mismatch, missing meta) moves to the next candidate —
    at most ``max_retries`` candidates are tried, so a directory of
    garbage fails fast instead of scanning forever.

    Structure mismatches do NOT walk back (older checkpoints were written
    by the same run configuration; retrying cannot fix a config change) —
    EXCEPT for tree names listed in ``allow_reset``, which are silently
    dropped from the restore and reported back so the caller keeps its
    fresh initialization for them (the launcher maps ``--allow-ckpt-reset``
    onto ``allow_reset=("ex_state",)``).

    Returns (step, {name: tree restored}, tuple of reset tree names).
    """
    candidates: list[int] = []
    lat = latest_step(path)
    if lat is not None:
        candidates.append(lat)
    for s in sorted(available_steps(path), reverse=True):
        if s not in candidates:
            candidates.append(s)
    if not candidates:
        raise CheckpointCorruptError(f"no checkpoints found at {path}")
    last_err: CheckpointError | None = None
    for _attempt, step in retry_mod.attempts(candidates, max_retries):
        live = dict(templates)
        reset: list[str] = []
        try:
            meta = read_meta(path, step)
            for name in list(live):
                mismatch = template_mismatch(meta, name, live[name])
                if mismatch is not None:
                    if name in allow_reset:
                        reset.append(name)
                        del live[name]
                    else:
                        raise CheckpointStructureError(name, mismatch)
            got_step, trees = restore(path, live, step=step)
            return got_step, trees, tuple(reset)
        except CheckpointCorruptError as e:
            last_err = e
            continue
    raise CheckpointCorruptError(
        f"no intact checkpoint among {candidates[:max_retries]} at {path}: "
        f"{last_err}"
    )
